"""Mathematical properties of the model primitives."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as nn
from repro.models.ssm import ssd_chunked
from repro.kernels.ref import ssd_ref


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
    r = nn.rope(x, jnp.arange(16), 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(r), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))

    def dot_at(i, j):
        qi = nn.rope(q, jnp.asarray([i]), 1e4)
        kj = nn.rope(k, jnp.asarray([j]), 1e4)
        return float(jnp.sum(qi * kj))

    assert np.isclose(dot_at(5, 3), dot_at(12, 10), atol=1e-4)
    assert np.isclose(dot_at(0, 0), dot_at(7, 7), atol=1e-4)


def test_rope_zero_position_identity():
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 2, 32))
    r = nn.rope(x, jnp.asarray([0]), 1e4)
    np.testing.assert_allclose(np.asarray(r), np.asarray(x), atol=1e-6)


# ---------------------------------------------------------------------------
# rms_norm
# ---------------------------------------------------------------------------


@given(st.integers(1, 8), st.integers(2, 128))
@settings(deadline=None, max_examples=20)
def test_rms_norm_unit_rms(b, d):
    x = jax.random.normal(jax.random.PRNGKey(b * 131 + d), (b, d)) * 3.0
    out = nn.rms_norm(x, jnp.zeros((d,)))
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=0.05)


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    a = nn.rms_norm(x, jnp.zeros((64,)))
    b = nn.rms_norm(10.0 * x, jnp.zeros((64,)))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k.astype(jnp.float32)) / math.sqrt(hd)
    dpos = jnp.arange(Sq)[:, None] - jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(dpos, bool)
    if causal:
        mask &= dpos >= 0
    if window:
        mask &= dpos < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd)


@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("chunk", [8, 64, 1024])
def test_chunked_attention_matches_naive(H, K, window, chunk):
    B, S, hd = 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = nn.attention(q, k, v, window=window, chunk=chunk)
    expect = _naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)


def test_decode_attention_matches_last_row():
    B, S, H, K, hd = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    full = _naive_attention(q, k, v)
    dec = nn.decode_attention(q[:, -1:], k, v, jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-4)


def test_banded_swa_ignores_distant_tokens():
    """SWA: perturbing a key outside the window changes nothing."""
    B, S, H, hd, w = 1, 128, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out1 = nn.attention(q, k, v, window=w, chunk=32)
    k2 = k.at[:, 10].set(100.0)
    v2 = v.at[:, 10].set(100.0)
    out2 = nn.attention(q, k2, v2, window=w, chunk=32)
    np.testing.assert_allclose(np.asarray(out1[:, 40:]), np.asarray(out2[:, 40:]),
                               atol=1e-5)


def test_pick_chunk_divides():
    for Sq in (17, 64, 256, 1500, 4096):
        c = nn._pick_chunk(Sq, 2, 8, 4096, 1024)
        assert Sq % c == 0 and c >= 1


# ---------------------------------------------------------------------------
# SSD dual form
# ---------------------------------------------------------------------------


def test_ssd_equals_attention_like_dual():
    """With A=0 (no decay) and dt=1, SSD reduces to (unnormalised) linear
    attention: y_t = C_t . sum_{j<=t} B_j x_j^T."""
    B, S, nh, P, N = 1, 16, 1, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (B, S, nh, P))
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    dt = jnp.ones((B, S, nh))
    A = jnp.zeros((nh,))
    D = jnp.zeros((nh,))
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=4)
    # manual linear attention
    expect = np.zeros((B, S, nh, P), np.float32)
    state = np.zeros((P, N), np.float32)
    for t in range(S):
        state = state + np.outer(np.asarray(x)[0, t, 0], np.asarray(Bm)[0, t])
        expect[0, t, 0] = state @ np.asarray(Cm)[0, t]
    np.testing.assert_allclose(np.asarray(y), expect, atol=1e-4, rtol=1e-3)


@given(st.integers(1, 3), st.integers(2, 4))
@settings(deadline=None, max_examples=10)
def test_ssd_chunked_matches_sequential(bi, nhi):
    S, P, N = 32, 8, 4
    key = jax.random.PRNGKey(bi * 31 + nhi)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bi, S, nhi, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bi, S, nhi))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (nhi,)))
    Bm = jax.random.normal(ks[3], (bi, S, N)) * 0.4
    Cm = jax.random.normal(ks[4], (bi, S, N)) * 0.4
    D = jnp.ones((nhi,))
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=8)
    y2, s2 = ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# cross entropy
# ---------------------------------------------------------------------------


def test_chunked_ce_matches_full():
    V, d, B, S = 97, 16, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    embed = jax.random.normal(ks[0], (V, d))
    x = jax.random.normal(ks[1], (B, S, d))
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    chunked = nn.cross_entropy(embed, x, labels, chunk=16)
    logits = jnp.einsum("bsd,vd->bsv", x, embed)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    full = jnp.mean(lse - gold)
    assert np.isclose(float(chunked), float(full), rtol=1e-5)


def test_ce_mask():
    V, d, B, S = 31, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    embed = jax.random.normal(ks[0], (V, d))
    x = jax.random.normal(ks[1], (B, S, d))
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    mask = jnp.zeros((B, S)).at[:, 8:].set(1.0)
    m = nn.cross_entropy(embed, x, labels, mask=mask, chunk=S)
    # perturbing masked labels does not change the loss
    labels2 = labels.at[:, :8].set((labels[:, :8] + 5) % V)
    m2 = nn.cross_entropy(embed, x, labels2, mask=mask, chunk=S)
    assert np.isclose(float(m), float(m2), rtol=1e-6)
