"""Per-architecture smoke tests: a REDUCED variant of each assigned
family runs one forward/train step on CPU — shapes are asserted and
outputs must be finite.  Decode/prefill consistency is checked for one
arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import build

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, with_labels=True, seq=S):
    batch = {"tokens": jax.random.randint(KEY, (B, seq), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, seq), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["vis_embeds"] = 0.1 * jnp.ones((B, cfg.n_vis_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        batch["enc_frames"] = 0.1 * jnp.ones((B, cfg.enc_seq, cfg.d_model), cfg.dtype)
    return batch


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch, reduced=True)
        m = build(cfg)
        out[arch] = (cfg, m, m.init(KEY))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(models, arch):
    cfg, m, params = models[arch]
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(m.train_loss))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"
    # one SGD step moves the loss
    lr = 0.1
    p2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    loss2 = jax.jit(m.train_loss)(p2, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_and_decode_shapes(models, arch):
    cfg, m, params = models[arch]
    batch = make_batch(cfg, with_labels=False)
    logits, cache = jax.jit(m.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    cache = pad_cache(cfg, cache, S + 4)
    db = {"token": batch["tokens"][:, :1], "pos": jnp.asarray(S, jnp.int32)}
    logits2, cache2 = jax.jit(m.decode_step)(params, cache, db)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
        assert a.shape == b.shape


def pad_cache(cfg, cache, target):
    """Grow sequence-indexed cache entries to ``target`` slots."""
    out = {}
    for k, v in cache.items():
        if k in ("k", "v") and v.ndim == 5:
            pad = target - v.shape[2]
            out[k] = jnp.pad(v, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
        elif k in ("c_kv", "k_pe"):
            pad = target - v.shape[2]
            out[k] = jnp.pad(v, [(0, 0), (0, 0), (0, pad), (0, 0)])
        else:
            out[k] = v
    return out


FAMILY_REPS = ["qwen3-14b", "deepseek-v2-lite-16b", "mamba2-780m",
               "zamba2-1.2b", "whisper-small", "gemma3-4b", "mixtral-8x7b"]


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_decode_consistency_with_prefill(models, arch):
    """Teacher forcing: prefill(S) last logits == prefill(S-1) + one
    decode step of token S-1."""
    cfg, m, params = models[arch]
    if cfg.n_experts:
        # capacity-based MoE drops different tokens at different S; use a
        # no-drop capacity so the two paths are comparable
        cfg = cfg.replace(moe_capacity_factor=float(cfg.n_experts))
        m = build(cfg)
    full = make_batch(cfg, with_labels=False)
    logits_full, _ = jax.jit(m.prefill)(params, full)

    prefix = {k: (v[:, : S - 1] if k in ("tokens",) else v) for k, v in full.items()}
    _, cache = jax.jit(m.prefill)(params, prefix)
    cache = pad_cache(cfg, cache, S)
    db = {"token": full["tokens"][:, S - 1: S], "pos": jnp.asarray(S - 1, jnp.int32)}
    logits_step, _ = jax.jit(m.decode_step)(params, cache, db)

    np.testing.assert_allclose(np.asarray(logits_step), np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


def test_vlm_loss_masks_vision_slots(models):
    cfg, m, params = models["internvl2-76b"]
    batch = make_batch(cfg)
    # change labels at the (masked) vision positions: loss must not move
    l1 = jax.jit(m.train_loss)(params, batch)
    batch2 = dict(batch)
    labels = np.asarray(batch["labels"]).copy()
    labels[:, : cfg.n_vis_tokens] = (labels[:, : cfg.n_vis_tokens] + 7) % cfg.vocab_size
    batch2["labels"] = jnp.asarray(labels)
    l2 = jax.jit(m.train_loss)(params, batch2)
    assert np.isclose(float(l1), float(l2), rtol=1e-5)


def test_mixtral_swa_window_active(models):
    """Tokens beyond the sliding window cannot influence the last logit."""
    cfg, m, params = models["mixtral-8x7b"]
    # capacity-based MoE dispatch is sequence-global (a token can displace
    # a later token past expert capacity); use a no-drop capacity so the
    # only cross-token path is attention
    cfg = cfg.replace(moe_capacity_factor=float(cfg.n_experts))
    m = build(cfg)
    assert cfg.window == 16  # reduced SWA
    seq = 3 * cfg.window
    batch = {"tokens": jax.random.randint(KEY, (1, seq), 0, cfg.vocab_size)}
    logits1, _ = jax.jit(m.prefill)(params, batch)
    toks = np.asarray(batch["tokens"]).copy()
    toks[0, 0] = (toks[0, 0] + 3) % cfg.vocab_size   # far outside any window
    logits2, _ = jax.jit(m.prefill)(params, {"tokens": jnp.asarray(toks)})
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2),
                               atol=1e-4)
