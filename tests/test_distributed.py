"""Distributed-semantics tests: the policy-driven shard_map paths
(sequence-parallel attention, group-wise MoE, ZeRO gathers) must compute
the SAME function as the plain single-host path.

Runs in a subprocess with 8 virtual CPU devices (jax locks the device
count at first init, so this cannot share the main pytest process).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.policy import Policy, use_policy
from repro.launch.sharding import param_shardings, make_policy
from repro.models.registry import build

mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S = 4, 32
failures = []

CASES = [("qwen3-14b", {}), ("mixtral-8x7b", {}),
         ("mixtral-8x7b-3e", {"n_experts": 3, "top_k": 2}),  # E % axis != 0
         ("mixtral-8x7b-2e", {"n_experts": 2, "top_k": 1, "d_ff_expert": 64}),  # virtual experts rep=2
         ("deepseek-v2-lite-16b", {}), ("mamba2-780m", {}), ("zamba2-1.2b", {})]
for arch, overrides in CASES:
    cfg = get_config(arch.split("-3e")[0].split("-2e")[0], reduced=True)
    if overrides:
        cfg = cfg.replace(**overrides)
    if cfg.n_experts:
        cfg = cfg.replace(moe_capacity_factor=float(cfg.n_experts))  # no drops
    # reduced dims must divide the tiny mesh axes
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    loss_fn = model.train_loss
    if cfg.n_experts:
        # the load-balance aux term is legitimately per-group under the
        # shard_map path; compare the data loss only
        from repro.models import moe as moe_mod
        loss_fn = lambda p, b: moe_mod.train_loss(p, cfg, b, aux_weight=0.0)

    loss_plain, grads_plain = jax.jit(jax.value_and_grad(loss_fn))(params, batch)

    pol = Policy(mesh=mesh, batch_axes=("data",), seq_axis="model",
                 head_axis="model", ep_axis="model")
    if cfg.family in ("ssm", "hybrid"):
        pol = Policy(mesh=mesh, batch_axes=("data",), seq_axis=None,
                     head_axis="model", ep_axis="model")
    with use_policy(pol):
        loss_pol, grads_pol = jax.jit(jax.value_and_grad(loss_fn))(params, batch)

    dl = abs(float(loss_plain) - float(loss_pol))
    gmax = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(grads_plain), jax.tree.leaves(grads_pol)))
    ok = dl < 2e-4 and gmax < 2e-2
    print(f"{arch}: dloss={dl:.2e} dgrad_max={gmax:.2e} {'OK' if ok else 'MISMATCH'}")
    if not ok:
        failures.append(arch)

if failures:
    raise SystemExit(f"mismatch: {failures}")
print("ALL_OK")
"""


@pytest.mark.slow
def test_policy_paths_match_plain_semantics():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=900, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr[-2000:])
    assert r.returncode == 0 and "ALL_OK" in r.stdout
