"""repro.participate — the composable client-participation API.

Load-bearing checks:

1. BITWISE NO-OP REGRESSION: ``participation="uniform"`` (the default)
   replays the pre-policy (PR-4 seed) trajectories bit-for-bit in
   ``run_fl`` and BOTH sim engines — including the dropout-scalar runs
   that now route through the ``avail:bernoulli`` shim.  The reference
   fingerprints were captured from the seed code on this platform
   immediately before the refactor.
2. HT unbiasedness: the pure Horvitz–Thompson estimator built from each
   policy's reported inclusion probabilities recovers the population
   mean under biased selection (powd's hypergeometric probabilities and
   importance sampling's Hansen–Hurwitz weights are EXACT).
3. Energy-budget monotonicity + recharge, availability phase lock, and
   the shim equivalence ``SimScenario(dropout=p)`` == ``avail:bernoulli:p``.
"""
import hashlib
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import get_scenario
from repro.core import LuarConfig, staleness_weighted_merge
from repro.core.units import build_units
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, run_fl
from repro.models.cnn import mlp_init, mlp_apply, softmax_xent
from repro.participate import (AvailBernoulli, AvailDiurnal, EnergyBudget,
                               POLICIES, ParticipationPolicy, PowerOfChoice,
                               RoundContext, Selection, UniformPolicy,
                               fairness_summary, ht_weights, make_policy,
                               parse_policy, register_policy)
from repro.sim import SimConfig, run_sim


@pytest.fixture(scope="module")
def task():
    x, y = gaussian_mixture(1200, n_classes=10, d=32, seed=0)
    parts = dirichlet_partition(y, 16, alpha=0.3, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(mlp_apply(p, xj), -1) == yj))}

    return dict(loss_fn=loss_fn, params=params, data={"x": x, "y": y},
                parts=parts, eval_fn=eval_fn)


def _cfg(**kw):
    kw.setdefault("client", ClientConfig(lr=0.05))
    kw.setdefault("rounds", 8)
    kw.setdefault("eval_every", 4)
    return FLConfig(n_clients=16, n_active=6, tau=3, batch_size=8, **kw)


def _fp(params) -> str:
    buf = np.concatenate([np.asarray(l, np.float64).ravel()
                          for l in jax.tree.leaves(params)])
    return hashlib.sha256(buf.tobytes()).hexdigest()[:16]


def _ctx(rng, n=16, k=6, cand=None, **kw):
    kw.setdefault("population", True)
    return RoundContext(rng=rng, n_clients=n, cohort_size=k,
                        candidates=np.arange(n) if cand is None else
                        np.asarray(cand, np.int64), **kw)


# ---------------------------------------------------------------------------
# grammar / registry
# ---------------------------------------------------------------------------


def test_registry_has_builtin_policies():
    assert {"uniform", "powd", "importance", "avail", "energy"} <= set(POLICIES)


@pytest.mark.parametrize("spec,cls", [
    ("uniform", UniformPolicy),
    ("powd:4", PowerOfChoice),
    ("avail:bernoulli:0.1", AvailBernoulli),
    ("avail:diurnal", AvailDiurnal),
    ("avail:diurnal:0.4:1200", AvailDiurnal),
    ("energy:20", EnergyBudget),
    ("energy:20,0.5,2", EnergyBudget),          # comma separators too
])
def test_parse_policy_specs(spec, cls):
    p = parse_policy(spec)
    assert isinstance(p, cls)


def test_parse_policy_rejects_unknown():
    with pytest.raises(ValueError, match="unknown participation policy"):
        parse_policy("powerpose:3")
    with pytest.raises(ValueError, match="availability kind"):
        parse_policy("avail:lunar")
    with pytest.raises(ValueError, match="importance signal"):
        parse_policy("importance:loss")


def test_parse_policy_validates_args():
    with pytest.raises(ValueError, match="rate"):
        parse_policy("avail:bernoulli:1.5")
    with pytest.raises(ValueError, match="duty fraction"):
        parse_policy("avail:diurnal:0")
    with pytest.raises(ValueError, match="capacity"):
        parse_policy("energy:-1")
    with pytest.raises(ValueError, match="candidate-set"):
        parse_policy("powd:0")


def test_register_policy_extensible():
    @register_policy("always_zero")
    class AlwaysZero(ParticipationPolicy):
        name = "always_zero"

        def select(self, ctx):
            return Selection(np.zeros(1, np.int64), np.ones(1), False, True)

    try:
        p = make_policy("always_zero", 8, seed=0)
        sel = p.select(_ctx(np.random.default_rng(0), n=8, k=2))
        assert list(sel.cohort) == [0]
    finally:
        del POLICIES["always_zero"]


def test_spec_roundtrip():
    for spec in ("uniform", "powd:4", "energy:20"):
        assert parse_policy(parse_policy(spec).spec()).spec() == \
            parse_policy(spec).spec()


# ---------------------------------------------------------------------------
# bitwise no-op regression vs the PR-4 seed trajectories
# ---------------------------------------------------------------------------

# fingerprints captured from the seed code (sha256 of the float64-flattened
# final params, first 16 hex chars) immediately before the participation
# refactor, same platform/jax build that runs this suite
_GOLD_RUN_FL = "13d3711a8b5d456c"
_GOLD_SYNC_FLAKY = "e358365ebc278d4e"
_GOLD_FEDBUFF = "d7da0364cb957567"
_GOLD_SYNC_DROPOUT = "e36416504e50dd19"
_GOLD_FEDBUFF_DROPOUT = "e1affc1effe9e60f"


def test_uniform_replays_run_fl_bitwise(task):
    cfg = _cfg(luar=LuarConfig(delta=2))          # participation defaults
    res = run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
                 cfg, task["eval_fn"])
    assert _fp(res.params) == _GOLD_RUN_FL
    assert [h["acc"] for h in res.history] == [0.9950000047683716, 1.0]
    assert res.comm_ratio == pytest.approx(0.409419242993, abs=1e-12)
    # satellite: run_fl now reports what it always accumulated
    assert res.n_uplinks_spent == cfg.n_active * cfg.rounds
    assert res.uploaded == pytest.approx(
        res.comm_ratio * res.n_uplinks_spent
        * sum(build_units(task["params"], "leaf").unit_bytes))


def test_uniform_replays_sync_sim_bitwise(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario="bimodal", deadline=60.0, sys_seed=3),
                  task["eval_fn"])
    # the ideal-regime sync engine replays run_fl (its own regression pins
    # that); the fingerprint pins the whole stack to the seed trajectory
    assert _fp(res.params) == _GOLD_RUN_FL
    assert res.sim_time == pytest.approx(1.3522589729315435, abs=1e-12)


@pytest.mark.slow
def test_uniform_replays_straggler_dropout_sync_bitwise(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario=get_scenario("bimodal_flaky"),
                                 deadline=0.1, sys_seed=1), task["eval_fn"])
    assert _fp(res.params) == _GOLD_SYNC_FLAKY
    assert (res.n_dropped, res.n_stragglers) == (3, 20)
    # per-client telemetry balances the aggregate counters
    assert res.dropout_count.sum() == res.n_dropped
    assert res.participation_count.sum() == cfg.n_active * cfg.rounds


def test_uniform_replays_fedbuff_bitwise(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario="bimodal", mode="fedbuff",
                                 buffer_size=4, concurrency=8, sys_seed=3),
                  task["eval_fn"])
    assert _fp(res.params) == _GOLD_FEDBUFF
    assert res.sim_time == pytest.approx(0.6602743216178293, abs=1e-12)
    assert (res.n_received, res.n_dispatched) == (32, 40)
    assert res.participation_count.sum() == res.n_dispatched


@pytest.mark.slow
def test_dropout_scalar_shim_replays_bitwise(task):
    """The retired SimScenario.dropout scalar routes through the
    avail:bernoulli shim now — and still replays the seed trajectories
    bit-for-bit (the shim warns, the numbers don't move)."""
    cfg = _cfg(luar=LuarConfig(delta=2))
    sc = get_scenario("uniform").replace(dropout=0.35)
    with pytest.warns(DeprecationWarning, match="avail:bernoulli"):
        res = run_sim(task["loss_fn"], task["params"], task["data"],
                      task["parts"], cfg, SimConfig(scenario=sc, sys_seed=5),
                      task["eval_fn"])
    assert _fp(res.params) == _GOLD_SYNC_DROPOUT
    assert res.n_dropped == 23
    with pytest.warns(DeprecationWarning, match="avail:bernoulli"):
        res = run_sim(task["loss_fn"], task["params"], task["data"],
                      task["parts"], cfg,
                      SimConfig(scenario=sc, mode="fedbuff", buffer_size=4,
                                concurrency=8, sys_seed=5), task["eval_fn"])
    assert _fp(res.params) == _GOLD_FEDBUFF_DROPOUT
    assert res.n_dropped == 24


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sync", "fedbuff"])
def test_avail_bernoulli_equals_dropout_scalar(task, mode):
    """Declaring the rate on the participation axis produces the SAME
    trajectory as the old scenario scalar: same uniform selection draws,
    same single systems-stream Bernoulli per dispatch."""
    cfg_old = _cfg(luar=LuarConfig(delta=2))
    cfg_new = _cfg(luar=LuarConfig(delta=2),
                   participation="avail:bernoulli:0.35")
    sim_kw = dict(sys_seed=5) if mode == "sync" else dict(
        mode="fedbuff", buffer_size=4, concurrency=8, sys_seed=5)
    with pytest.warns(DeprecationWarning):
        old = run_sim(task["loss_fn"], task["params"], task["data"],
                      task["parts"], cfg_old,
                      SimConfig(scenario=get_scenario("uniform").replace(
                          dropout=0.35), **sim_kw), task["eval_fn"])
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)   # no shim fires
        new = run_sim(task["loss_fn"], task["params"], task["data"],
                      task["parts"], cfg_new,
                      SimConfig(scenario="uniform", **sim_kw),
                      task["eval_fn"])
    assert _fp(old.params) == _fp(new.params)
    assert old.n_dropped == new.n_dropped
    assert old.sim_time == new.sim_time
    assert np.array_equal(old.dropout_count, new.dropout_count)


# ---------------------------------------------------------------------------
# HT-reweighting unbiasedness (the estimator property)
# ---------------------------------------------------------------------------


def _ht_estimate(policy, d, k, n_trials, seed):
    """Monte-Carlo mean (and its standard error) of the pure HT estimator
    (1/N) sum_i d_i w_i over repeated policy selections."""
    n = len(d)
    rng = np.random.default_rng(seed)
    trials = np.empty(n_trials)
    for i in range(n_trials):
        sel = policy.select(_ctx(rng, n=n, k=k))
        w = ht_weights(sel)
        trials[i] = sum(wi * d[int(c)] for wi, c in zip(w, sel.cohort)) / n
    return trials.mean(), trials.std(ddof=1) / math.sqrt(n_trials)


@pytest.mark.slow
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=5),
       st.integers(min_value=8, max_value=14))
@settings(deadline=None, max_examples=10)
def test_powd_ht_estimator_unbiased(seed, k, n):
    """powd's hypergeometric inclusion probabilities are exact: the pure
    HT estimator recovers the population mean even though selection is
    maximally biased toward high-loss clients."""
    rng = np.random.default_rng(seed)
    d = rng.normal(size=n) * 3.0
    policy = make_policy("powd:6", n, seed=seed)
    # give the policy a biased loss view CORRELATED with the data so a
    # probability bug shows up as estimator bias, not noise
    policy.observe_round(np.arange(n), losses=np.abs(d) + rng.random(n))
    est, se = _ht_estimate(policy, d, k, n_trials=3000, seed=seed + 1)
    assert est == pytest.approx(d.mean(), abs=6 * se + 1e-9)


@pytest.mark.slow
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=6),
       st.integers(min_value=8, max_value=14))
@settings(deadline=None, max_examples=10)
def test_importance_hh_estimator_unbiased(seed, k, n):
    """importance:norm samples with replacement; Hansen–Hurwitz weights
    1/(k p_i) make every draw an exactly unbiased term."""
    rng = np.random.default_rng(seed)
    d = rng.normal(size=n) * 2.0
    policy = make_policy("importance:norm", n, seed=seed)
    policy.observe_round(np.arange(n), update_norms=np.abs(d) + 0.1)
    est, se = _ht_estimate(policy, d, k, n_trials=3000, seed=seed + 1)
    assert est == pytest.approx(d.mean(), abs=6 * se + 1e-9)


@pytest.mark.slow
def test_powd_inclusion_probs_match_empirical_frequency():
    """The hypergeometric formula against brute force: empirical selection
    frequency over many draws must match the reported pi_i per client."""
    n, k, trials = 10, 3, 4000
    policy = make_policy("powd:5", n, seed=0)
    policy.observe_round(np.arange(n), losses=np.linspace(2.0, 0.5, n))
    rng = np.random.default_rng(42)
    freq = np.zeros(n)
    probs = np.full(n, np.nan)
    for _ in range(trials):
        sel = policy.select(_ctx(rng, n=n, k=k))
        for c, p in zip(sel.cohort, sel.probs):
            freq[int(c)] += 1
            probs[int(c)] = p
    freq /= trials
    seen = ~np.isnan(probs)
    # every client's pi should be visited; 4 sigma binomial tolerance
    assert seen.all()
    tol = 4 * np.sqrt(probs * (1 - probs) / trials) + 1e-3
    assert np.all(np.abs(freq - probs) <= tol)
    # the design's inclusion probabilities must sum to the cohort size
    assert probs.sum() == pytest.approx(k, rel=1e-9)


def test_ht_weights_validates_probs():
    sel = Selection(np.array([0]), np.array([0.0]), False, False)
    with pytest.raises(ValueError, match="non-positive"):
        ht_weights(sel)


def test_merge_ht_ones_is_noop_and_weighting_shifts():
    tree = {"a": jnp.stack([jnp.ones(4), -jnp.ones(4)])}
    stal = jnp.zeros(2, jnp.int32)
    base = staleness_weighted_merge(tree, stal)
    same = staleness_weighted_merge(tree, stal, ht=jnp.ones(2))
    np.testing.assert_array_equal(np.asarray(base["a"]), np.asarray(same["a"]))
    # upweighting the second client pulls the merge negative
    tilted = staleness_weighted_merge(tree, stal, ht=jnp.asarray([1.0, 3.0]))
    assert np.all(np.asarray(tilted["a"]) < 0)


# ---------------------------------------------------------------------------
# energy budgets
# ---------------------------------------------------------------------------


def test_energy_depletes_monotonically_and_recharges():
    p = make_policy("energy:10:1:1", 4, seed=0)     # cap 10, recharge 1 J/s
    levels = []
    for i in range(5):
        p.observe_dispatch(0, now=float(i), cost_s=1.0)  # busy back-to-back
        levels.append(p.battery[0])
    assert all(b2 < b1 for b1, b2 in zip(levels, levels[1:]))
    # client 1 stayed idle the whole time: fully charged
    p._accrue(5.0)
    assert p.battery[1] == pytest.approx(10.0)
    # idle time recharges client 0 (busy until t=5, then idle 3 s)
    drained = p.battery[0]
    p._accrue(8.0)
    assert p.battery[0] == pytest.approx(min(10.0, drained + 3.0))


def test_energy_never_negative_and_dead_unselectable():
    p = make_policy("energy:2:0.5:1", 6, seed=0)
    p.observe_dispatch(3, now=0.0, cost_s=100.0)    # drain far past zero
    assert p.battery[3] == 0.0                      # clamped, not negative
    rng = np.random.default_rng(0)
    for _ in range(20):
        sel = p.select(_ctx(rng, n=6, k=3, now=0.0))
        assert 3 not in set(int(c) for c in sel.cohort)
    # recharge lifts it back into the selectable pool (0.5 J/s while idle;
    # busy until t=100, so by t=110 it holds ~5 J)
    picked = set()
    for _ in range(50):
        sel = p.select(_ctx(rng, n=6, k=3, now=110.0))
        picked |= set(int(c) for c in sel.cohort)
    assert 3 in picked


def test_energy_sync_empty_rounds_advance_clock_and_revive(task):
    """All batteries die after one cohort: the sync engine must still
    advance virtual time on the empty rounds, so idle recharge can
    revive the population (a frozen clock would silently skip every
    remaining round)."""
    cfg = FLConfig(n_clients=16, n_active=16, tau=3, batch_size=8, rounds=8,
                   eval_every=4, client=ClientConfig(lr=0.05),
                   luar=LuarConfig(delta=2),
                   participation="energy:1:0.5:100")  # one round drains 10 J
    res = run_sim(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg, SimConfig(scenario="uniform"),
                  task["eval_fn"])
    # revival: strictly more than the single first-round cohort trained
    assert res.participation_count.sum() > cfg.n_clients
    assert res.rounds_done > 1
    assert res.sim_time > 0


def test_energy_fedbuff_starved_slots_retry(task):
    """Every first-wave battery dies mid-flight: freed slots find a dead
    idle pool, go starved, and must be re-fed on later events once
    recharge revives somebody — not retired permanently."""
    cfg = FLConfig(n_clients=16, n_active=16, tau=3, batch_size=8, rounds=6,
                   eval_every=3, client=ClientConfig(lr=0.05),
                   luar=LuarConfig(delta=2), participation="energy:1:5:10")
    res = run_sim(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg,
                  SimConfig(scenario="lognormal", mode="fedbuff",
                            buffer_size=4, concurrency=16), task["eval_fn"])
    assert res.participation_count.sum() > 16     # redispatch happened
    assert res.rounds_done == cfg.rounds


@pytest.mark.parametrize("max_sim_time", [math.inf, 100.0])
def test_energy_fedbuff_simultaneous_death_wakes_clock(task, max_sim_time):
    """Identical (uniform) resources make the whole first wave arrive at
    ONE virtual instant with every battery at zero — no event is left to
    move the clock, so the WAKE path must idle the server until recharge
    revives the pool instead of silently ending the run early.  The
    finite-cutoff variant pins the guard that must IGNORE the permanent
    max_sim_time DEADLINE sentinel when deciding nothing else will move
    the clock (it used to suppress the WAKE and fast-forward the run to
    the cutoff)."""
    cfg = FLConfig(n_clients=16, n_active=8, tau=3, batch_size=8, rounds=6,
                   eval_every=3, client=ClientConfig(lr=0.05),
                   luar=LuarConfig(delta=2), participation="energy:1:5:100")
    res = run_sim(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg,
                  SimConfig(scenario="uniform", mode="fedbuff",
                            buffer_size=4, concurrency=8,
                            max_sim_time=max_sim_time), task["eval_fn"])
    assert res.rounds_done == cfg.rounds
    assert res.participation_count.sum() > 8


def test_sync_dead_population_keeps_eval_cadence(task):
    """A no-recharge population dies after one dispatch each; the empty
    rounds must still report on the eval cadence (matching run_fl), so
    history ends with an honest terminal row instead of going stale."""
    cfg = FLConfig(n_clients=16, n_active=8, tau=3, batch_size=8, rounds=8,
                   eval_every=4, client=ClientConfig(lr=0.05),
                   luar=LuarConfig(delta=2), participation="energy:2:0:100")
    res = run_sim(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg, SimConfig(scenario="uniform"),
                  task["eval_fn"])
    assert res.participation_count.max() == 1      # everyone died at once
    assert [h["round"] for h in res.history] == [4, 8]
    assert res.sim_time > 0                        # idle time still passed


def test_run_fl_diurnal_rotates_over_the_run(task):
    """The clockless engine maps the diurnal cycle onto one period per
    run, so the availability window sweeps the whole population instead
    of freezing on the round-0 subset."""
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=16,
               participation="avail:diurnal")
    res = run_fl(task["loss_fn"], task["params"], task["data"],
                 task["parts"], cfg, task["eval_fn"])
    assert (res.participation_count > 0).all()     # everyone got a window


def test_energy_empty_population_skips_rounds(task):
    """A population whose batteries die and never recharge: run_fl keeps
    going (model frozen on empty rounds) instead of crashing."""
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=6,
               participation="energy:2:0:1")        # 2 rounds, no recharge
    res = run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
                 cfg, task["eval_fn"])
    # every client trained at most twice before dying
    assert res.participation_count.max() <= 2
    assert res.n_uplinks_spent < cfg.n_active * cfg.rounds
    assert res.history                              # eval still reported


# ---------------------------------------------------------------------------
# availability traces
# ---------------------------------------------------------------------------


def test_diurnal_availability_phase_lock_rotates():
    p = make_policy("avail:diurnal:0.5:600", 12, seed=0)
    ids = np.arange(12)
    a0 = set(p.available(ids, now=0.0).tolist())
    a_half = set(p.available(ids, now=300.0).tolist())    # half a cycle later
    assert 0 < len(a0) < 12                  # a strict subset is available
    assert a0 != a_half                      # and WHO rotates with the cycle
    # half a period apart the availability sets are (near-)complementary
    assert len(a0 & a_half) <= 2
    # full-duty fraction: everyone, always
    full = make_policy("avail:diurnal:1:600", 12, seed=0)
    assert len(full.available(ids, now=123.0)) == 12


def test_diurnal_cohort_shrinks_rather_than_conscripts():
    p = make_policy("avail:diurnal:0.25:600", 8, seed=0)
    rng = np.random.default_rng(0)
    sel = p.select(_ctx(rng, n=8, k=6, now=0.0))
    avail = set(p.available(np.arange(8), 0.0).tolist())
    assert set(int(c) for c in sel.cohort) <= avail
    assert len(sel.cohort) <= max(len(avail), 1)


@pytest.mark.slow
def test_powd_biases_toward_high_loss_clients(task):
    """End-to-end: power-of-choice trains loss-heavy clients more often
    than cold ones (the bias the HT weights correct in aggregation)."""
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=12,
               participation="powd:12")
    res = run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
                 cfg, task["eval_fn"])
    counts = res.participation_count
    assert counts.sum() == cfg.n_active * 12
    assert res.fairness["max"] > res.fairness["min"]   # visibly biased
    assert res.history[-1]["acc"] > 0.8                # and still converges


@pytest.mark.slow
def test_biased_policies_run_fedbuff_end_to_end(task):
    """avail:diurnal and powd under buffered async: HT weights thread
    through the staleness merge, fairness telemetry lands on SimResult."""
    for part in ("avail:diurnal:0.5", "powd:8"):
        cfg = _cfg(luar=LuarConfig(delta=2), participation=part)
        res = run_sim(task["loss_fn"], task["params"], task["data"],
                      task["parts"], cfg,
                      SimConfig(scenario="bimodal", mode="fedbuff",
                                buffer_size=4, concurrency=8),
                      task["eval_fn"])
        assert res.rounds_done == cfg.rounds
        assert res.participation_count.sum() == res.n_dispatched
        assert res.fairness["max"] >= res.fairness["min"]
        assert res.history[-1]["acc"] > 0.5


def test_fairness_summary_shape():
    f = fairness_summary(np.array([0, 2, 4]))
    assert f == {"min": 0.0, "median": 2.0, "max": 4.0}
    assert fairness_summary(np.zeros(0)) == {"min": 0.0, "median": 0.0,
                                             "max": 0.0}


def test_uniform_policy_probs_and_uniform_flag():
    p = make_policy("uniform", 16, seed=0)
    rng = np.random.default_rng(0)
    sel = p.select(_ctx(rng, n=16, k=6))
    assert sel.uniform and not sel.with_replacement
    assert np.allclose(sel.probs, 6 / 16)
    # redispatch shape: one pick from a subset pool
    sel = p.select(_ctx(rng, n=16, k=1, cand=[3, 7, 9], population=False))
    assert len(sel.cohort) == 1 and int(sel.cohort[0]) in (3, 7, 9)
    assert np.allclose(sel.probs, 1 / 3)
